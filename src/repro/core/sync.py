"""Synchronization-op insertion (paper Table III).

Given a complete :class:`Schedule` (traversal order + stream binding), the
schedule is *expanded* into the actual executed item sequence by inserting
the synchronization operations the CUDA/TPU runtime requires:

  u kind      v kind        inserted
  ----------  ------------  ----------------------------------------------
  CPU         CPU/BoundGPU  none (CPU ops are synchronous)
  BoundGPU_i  CPU           CER-after-u  ->  CES-b4-v
  BoundGPU_i  BoundGPU_i    none (same stream: implicit ordering)
  BoundGPU_i  BoundGPU_j    CER-after-u  ->  CSWE-b4-v     (i != j)

CER = cudaEventRecord (on u's stream, right after u)
CES = cudaEventSynchronize (host blocks until the event)
CSWE = cudaStreamWaitEvent (v's stream waits for the event)

The names mirror the paper's automatically generated names
("CES-b4-PostSend", "CER-after-Pack"), so generated rules read the same.

On TPU these map to token joins between serialization chains
(:mod:`repro.core.executor`); the insertion *rules* are identical.
"""
from __future__ import annotations

import dataclasses
import weakref

from repro.core.dag import BoundOp, Graph, OpKind, Schedule


@dataclasses.dataclass(frozen=True)
class ExpandedItem:
    """One item of an expanded schedule.

    kind:   'op'   — an original DAG vertex (stream set for GPU ops)
            'CER'  — event record, anchored after ``anchor`` (on its stream)
            'CES'  — host event sync before ``anchor``, waiting on ``waits``
            'CSWE' — stream wait event before ``anchor`` (on ``stream``),
                     waiting on ``waits``
    """

    name: str
    kind: str
    stream: int | None = None
    anchor: str | None = None
    waits: tuple[str, ...] = ()


def expand(graph: Graph, schedule: Schedule) -> list[ExpandedItem]:
    """Insert Table III sync ops into ``schedule``.

    Insertion is deterministic given (order, streams): a single CER per
    recorded GPU op (immediately after it), and a single CES/CSWE per
    consumer (immediately before it) that waits on all required events.
    """
    streams = schedule.streams()
    expanded: list[ExpandedItem] = []
    recorded: set[str] = set()  # GPU ops that already have a CER

    for item in schedule.items:
        op = graph.ops[item.name]
        # Which predecessors require an event wait before this op?
        ces_waits: list[str] = []
        cswe_waits: list[str] = []
        for u in sorted(graph.preds[item.name]):
            uop = graph.ops[u]
            if uop.kind is not OpKind.GPU:
                continue  # CPU->anything: no sync needed
            if op.kind is OpKind.GPU and streams[u] == item.stream:
                continue  # same stream: implicit ordering
            if op.kind is OpKind.GPU:
                cswe_waits.append(u)
            else:
                ces_waits.append(u)

        # Events must have been recorded right after their producing op; we
        # retro-check: the producing op appears earlier in the traversal, so
        # its CER is already in `expanded` (inserted below when u was seen).
        for w in ces_waits + cswe_waits:
            assert w in recorded, f"event for {w} not recorded"

        if ces_waits:
            expanded.append(ExpandedItem(
                name=f"CES-b4-{item.name}", kind="CES",
                anchor=item.name, waits=tuple(ces_waits)))
        if cswe_waits:
            expanded.append(ExpandedItem(
                name=f"CSWE-b4-{item.name}", kind="CSWE",
                anchor=item.name, stream=item.stream,
                waits=tuple(cswe_waits)))

        expanded.append(ExpandedItem(
            name=item.name, kind="op", stream=item.stream))

        # Record an event after every GPU op whose completion any later
        # differently-synchronized consumer might need. A CER is cheap; the
        # paper inserts it for every GPU op that feeds a CPU op or a
        # different stream. We insert lazily-but-eagerly: if ANY successor
        # is CPU or could land on another stream, record now (succ streams
        # are known since the schedule is complete).
        if op.kind is OpKind.GPU and item.name not in recorded:
            needs_event = False
            for v in graph.succs[item.name]:
                vop = graph.ops[v]
                if vop.kind is not OpKind.GPU:
                    needs_event = True
                elif streams.get(v) != item.stream:
                    needs_event = True
            if needs_event:
                expanded.append(ExpandedItem(
                    name=f"CER-after-{item.name}", kind="CER",
                    anchor=item.name, stream=item.stream))
                recorded.add(item.name)

    return expanded


# Featurization expands every schedule in a corpus, and only needs the
# item *names*; constructing ExpandedItem records for each of them is
# the dominant cost of :func:`repro.core.features.featurize`. The fast
# path below re-derives just the name sequence from per-graph tables
# (cached weakly, so graphs stay collectable). It is locked to
# :func:`expand` by tests/test_core_dag.py::test_expanded_names_
# matches_expand.

_SYNC_TABLES: "weakref.WeakKeyDictionary[Graph, tuple]" = \
    weakref.WeakKeyDictionary()


def _sync_tables(graph: Graph) -> tuple:
    cached = _SYNC_TABLES.get(graph)
    if cached is not None and cached[0] == graph.version:
        return cached[1]
    is_gpu = {n: op.kind is OpKind.GPU for n, op in graph.ops.items()}
    gpu_preds = {n: tuple(u for u in sorted(p) if is_gpu[u])
                 for n, p in graph.preds.items()}
    succ_info = {n: tuple((v, is_gpu[v]) for v in graph.succs[n])
                 for n in graph.ops}
    ces = {n: f"CES-b4-{n}" for n in graph.ops}
    cswe = {n: f"CSWE-b4-{n}" for n in graph.ops}
    cer = {n: f"CER-after-{n}" for n in graph.ops}
    tables = (is_gpu, gpu_preds, succ_info, ces, cswe, cer)
    _SYNC_TABLES[graph] = (graph.version, tables)
    return tables


def expanded_names(graph: Graph, schedule: Schedule) -> list[str]:
    """Names of the expanded sequence (fast path of :func:`expand`)."""
    is_gpu, gpu_preds, succ_info, ces, cswe, cer = _sync_tables(graph)
    streams = {it.name: it.stream for it in schedule.items
               if it.stream is not None}
    out: list[str] = []
    for it in schedule.items:
        name = it.name
        gp = gpu_preds[name]
        if is_gpu[name]:
            st = it.stream
            for u in gp:
                if streams[u] != st:
                    out.append(cswe[name])
                    break
            out.append(name)
            for v, v_gpu in succ_info[name]:
                if not v_gpu or streams.get(v) != st:
                    out.append(cer[name])
                    break
        else:
            if gp:
                out.append(ces[name])
            out.append(name)
    return out
