"""Pure-jnp oracle for the pack (gather) kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pack_ref(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[j] = x[idx[j]] — halo/send-buffer packing."""
    return x[idx]
