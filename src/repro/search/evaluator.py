"""Compatibility shim: the evaluator now lives in :mod:`repro.engine`.

``BatchEvaluator`` (the serial ``"sim"`` backend) and
``canonical_key`` moved to the pluggable evaluation-engine subsystem —
:mod:`repro.engine.base` — where they share the memo-cache / noise /
budget-accounting layer with the vectorized, process-pool, and
wall-clock backends. Import from :mod:`repro.engine` (or keep importing
from here / :mod:`repro.search`; both stay supported).
"""
from repro.engine.base import BatchEvaluator, EvaluatorBase, canonical_key

__all__ = ["BatchEvaluator", "EvaluatorBase", "canonical_key"]
