"""engine.wallclock: real measurements behind the evaluator contract."""
import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S


@pytest.fixture(scope="module")
def small_spmv():
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    impls, env = E.demo_spmv_impls(g, n=8)
    return g, impls, env


def test_wallclock_requires_impls():
    g = C.spmv_dag()
    with pytest.raises(ValueError, match="impls"):
        E.make_evaluator(g, "wallclock")


def test_wallclock_measures_and_checks_values(small_spmv):
    g, impls, env = small_spmv
    ev = E.make_evaluator(g, "wallclock", impls=impls, env=env,
                          repeats=3)
    scheds = list(C.enumerate_schedules(g, 2))[:6]
    times = ev.evaluate(scheds)
    assert all(t > 0.0 for t in times)
    assert ev.cache_misses == len(scheds)
    assert ev.n_checked == len(scheds)  # every unique schedule verified
    # Memoized: re-evaluation is a pure cache hit, no new measurement.
    again = ev.evaluate(scheds)
    assert again == times
    assert ev.cache_misses == len(scheds)
    assert ev.n_checked == len(scheds)


def test_wallclock_value_gate_catches_divergence(small_spmv):
    """An impl with an undeclared dependency (reads a value the DAG has
    no edge for, so sync insertion cannot order it) computes different
    values under different schedules — the correctness gate must trip."""
    g, impls, env = small_spmv
    import jax.numpy as jnp
    bad = dict(impls)
    bad["yR"] = C.op_impl(lambda x, y: x + y, ["xR", "yL"], ["yR"])
    env = dict(env)
    env["yL"] = jnp.zeros((8,), jnp.float32)   # placeholder until yL runs
    scheds = list(C.enumerate_schedules(g, 2))
    ev = E.make_evaluator(g, "wallclock", impls=bad, env=env, repeats=1)
    ref = E.reference_schedule(g)

    def yl_first(s):
        order = s.order()
        return order.index("yL") < order.index("yR")

    # A schedule ordering yL/yR opposite to the reference sees a
    # different "yL" value at its undeclared read.
    good = next(s for s in scheds if yl_first(s) == yl_first(ref))
    target = next(s for s in scheds if yl_first(s) != yl_first(ref))
    with pytest.raises(AssertionError, match="yR"):
        ev.evaluate([good, target])
    # The measurement completed before the failure is salvaged: the
    # good schedule is cached and a retry doesn't recompile it. The
    # aborted batch counted nothing, so nothing has hit a meter yet.
    assert len(ev) == 1
    assert (ev.cache_hits, ev.cache_misses) == (0, 0)
    t = ev.evaluate_one(good)
    assert t > 0.0
    # Budget-accounting regression (the salvage-miscount bug): that
    # measurement was *paid* — its first post-salvage lookup must be a
    # miss, not a free cache hit that undercounts sim_budget.
    assert (ev.cache_hits, ev.cache_misses) == (0, 1)
    # Only the first lookup: afterwards it is an ordinary memo hit.
    assert ev.evaluate_one(good) == t
    assert (ev.cache_hits, ev.cache_misses) == (1, 1)


def test_wallclock_end_to_end_search(small_spmv):
    """The acceptance lock: an end-to-end search on CPU through the
    wallclock backend, with value-correctness asserted, producing a
    usable dataset; the analytic backend completes the same search."""
    g, impls, env = small_spmv
    ev = E.make_evaluator(g, "wallclock", impls=impls, env=env,
                          repeats=3)
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=10,
                       evaluator=ev)
    assert len(res.schedules) >= 2
    assert all(t > 0.0 for t in res.times)
    assert ev.n_checked == res.cache_misses  # every sim value-checked
    # The same search completes under the analytic objective too (the
    # wallclock path swaps cleanly back; different objective, so the
    # explored sets may differ).
    res_sim = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=10,
                           backend="sim")
    assert len(res_sim.schedules) >= 2


def test_reference_schedule_is_valid(small_spmv):
    g, _, _ = small_spmv
    C.validate_schedule(g, E.reference_schedule(g))
