"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887].

long_500k note: at 524k context the attention layers use a sliding
window (the mamba layers carry unbounded context in O(1) state); the
launch layer applies ``attn_window`` for that shape cell only.
"""
from repro.models.config import ModelConfig, MoeConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba",
            "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, mlp="swiglu",
    pattern=_PATTERN,
    moe=MoeConfig(n_experts=16, top_k=2, n_shared=0, d_expert=14336),
    moe_every=2,
    mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, mlp="swiglu",
    pattern=_PATTERN,
    moe=MoeConfig(capacity_factor=8.0, n_experts=4, top_k=2, n_shared=0, d_expert=128),
    moe_every=2,
    mamba_d_state=8, mamba_expand=2, mamba_d_conv=4,
)
