"""CART decision tree (from scratch) + the paper's Algorithm 1.

This container has no scikit-learn, so we implement the subset of
``DecisionTreeClassifier`` the paper uses: CART with gini impurity,
``class_weight='balanced'``, ``max_leaf_nodes`` (best-first growth by
weighted impurity decrease, like sklearn) and ``max_depth``.

The tree is intentionally allowed to overfit (paper §IV-C): it describes
the explored design space; generalization is measured separately
(Table V).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np


@dataclasses.dataclass
class TreeNode:
    node_id: int
    depth: int
    indices: np.ndarray                  # training rows in this node
    value: np.ndarray                    # weighted class counts
    n_samples: int
    feature: int | None = None           # split feature (None = leaf)
    threshold: float = 0.5
    left: "TreeNode | None" = None
    right: "TreeNode | None" = None

    @property
    def is_leaf(self) -> bool:
        return self.feature is None

    def majority_class(self) -> int:
        return int(np.argmax(self.value))


def _gini(weighted_counts: np.ndarray) -> float:
    tot = weighted_counts.sum()
    if tot <= 0:
        return 0.0
    p = weighted_counts / tot
    return float(1.0 - np.sum(p * p))


@dataclasses.dataclass
class _Candidate:
    gain: float
    feature: int
    threshold: float
    left_idx: np.ndarray
    right_idx: np.ndarray
    left_value: np.ndarray
    right_value: np.ndarray


class DecisionTree:
    """CART classifier (gini, balanced class weights, best-first growth)."""

    def __init__(self, max_leaf_nodes: int, max_depth: int | None = None):
        if max_leaf_nodes < 2:
            raise ValueError("max_leaf_nodes must be >= 2")
        self.max_leaf_nodes = max_leaf_nodes
        self.max_depth = max_depth
        self.root: TreeNode | None = None
        self.n_classes = 0
        self.classes_: np.ndarray | None = None

    # -- fitting ----------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray) -> "DecisionTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y)
        self.classes_, y_enc = np.unique(y, return_inverse=True)
        self.n_classes = len(self.classes_)
        n = len(y_enc)
        # class_weight='balanced': w_c = n / (k * n_c)
        counts = np.bincount(y_enc, minlength=self.n_classes)
        class_w = np.where(counts > 0,
                           n / (self.n_classes * np.maximum(counts, 1)), 0.0)
        sample_w = class_w[y_enc]

        def node_value(idx: np.ndarray) -> np.ndarray:
            return np.bincount(y_enc[idx], weights=sample_w[idx],
                               minlength=self.n_classes)

        ids = itertools.count()
        all_idx = np.arange(n)
        self.root = TreeNode(next(ids), 0, all_idx, node_value(all_idx),
                             n_samples=n)

        def best_split(node: TreeNode) -> _Candidate | None:
            idx = node.indices
            if len(idx) < 2:
                return None
            parent_imp = _gini(node.value)
            if parent_imp == 0.0:
                return None
            tot_w = node.value.sum()
            best: _Candidate | None = None
            Xn = X[idx]
            for f in range(X.shape[1]):
                col = Xn[:, f]
                vals = np.unique(col)
                if len(vals) < 2:
                    continue
                thresholds = (vals[:-1] + vals[1:]) / 2.0
                for t in thresholds:
                    mask = col <= t
                    li, ri = idx[mask], idx[~mask]
                    lv, rv = node_value(li), node_value(ri)
                    lw, rw = lv.sum(), rv.sum()
                    child_imp = (lw * _gini(lv) + rw * _gini(rv)) / tot_w
                    gain = tot_w * (parent_imp - child_imp)
                    if best is None or gain > best.gain + 1e-15:
                        best = _Candidate(gain, f, float(t), li, ri, lv, rv)
            # Zero-gain splits are allowed (CART/sklearn semantics): XOR-
            # style labels need a gainless first split to become
            # separable; max_leaf_nodes bounds growth.
            if best is not None and best.gain < -1e-12:
                return None
            return best

        # Best-first growth: split the frontier leaf with the largest
        # impurity-decrease until max_leaf_nodes is reached.
        heap: list[tuple[float, int, TreeNode, _Candidate]] = []

        def push(node: TreeNode) -> None:
            if self.max_depth is not None and node.depth >= self.max_depth:
                return
            cand = best_split(node)
            if cand is not None:
                heapq.heappush(heap, (-cand.gain, node.node_id, node, cand))

        push(self.root)
        n_leaves = 1
        while heap and n_leaves < self.max_leaf_nodes:
            _, _, node, cand = heapq.heappop(heap)
            node.feature = cand.feature
            node.threshold = cand.threshold
            node.left = TreeNode(next(ids), node.depth + 1, cand.left_idx,
                                 cand.left_value, len(cand.left_idx))
            node.right = TreeNode(next(ids), node.depth + 1, cand.right_idx,
                                  cand.right_value, len(cand.right_idx))
            n_leaves += 1
            push(node.left)
            push(node.right)
        return self

    # -- inference ----------------------------------------------------------
    def _leaf(self, x: np.ndarray) -> TreeNode:
        node = self.root
        assert node is not None, "tree not fitted"
        while not node.is_leaf:
            node = node.left if x[node.feature] <= node.threshold \
                else node.right
        return node

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.array([self._leaf(x).majority_class() for x in X])
        return self.classes_[out]

    def training_error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != np.asarray(y)))

    # -- structure ----------------------------------------------------------
    def leaves(self) -> list[TreeNode]:
        out: list[TreeNode] = []

        def walk(node: TreeNode) -> None:
            if node.is_leaf:
                out.append(node)
            else:
                walk(node.left)
                walk(node.right)

        if self.root is not None:
            walk(self.root)
        return out

    def depth(self) -> int:
        def d(node: TreeNode) -> int:
            if node.is_leaf:
                return node.depth
            return max(d(node.left), d(node.right))
        return d(self.root) if self.root is not None else 0

    def n_leaves(self) -> int:
        return len(self.leaves())

    def paths(self) -> list[tuple[list[tuple[int, float, bool]], TreeNode]]:
        """All (path, leaf) pairs; path = [(feature, threshold, went_right)]."""
        out = []

        def walk(node: TreeNode, path):
            if node.is_leaf:
                out.append((list(path), node))
                return
            walk(node.left, path + [(node.feature, node.threshold, False)])
            walk(node.right, path + [(node.feature, node.threshold, True)])

        if self.root is not None:
            walk(self.root, [])
        return out


@dataclasses.dataclass
class TreeSearchTrace:
    max_leaf_nodes: list[float]
    errors: list[float]
    depths: list[int]


def algorithm1(X: np.ndarray, y: np.ndarray,
               initial_leaves: int | None = None,
               trace: TreeSearchTrace | None = None) -> DecisionTree:
    """Paper Algorithm 1: grow max_leaf_nodes until error stops shrinking.

    ``train(mln)`` fits a tree with max_leaf_nodes=mln and
    max_depth=mln-1. Starting leaf count = number of classes (the paper's
    listing initialises with 2; we use max(2, n_classes) per §IV-C text).
    """
    n_classes = len(np.unique(y))
    mln = initial_leaves if initial_leaves is not None \
        else max(2, n_classes)

    def train(k: int) -> tuple[float, DecisionTree]:
        t = DecisionTree(max_leaf_nodes=k, max_depth=k - 1).fit(X, y)
        e = t.training_error(X, y)
        if trace is not None:
            trace.max_leaf_nodes.append(k)
            trace.errors.append(e)
            trace.depths.append(t.depth())
        return e, t

    err, clf = train(mln)
    improved = True
    while improved and err > 0.0:
        improved = False
        for i in range(1, 6):
            cur, nclf = train(mln + i)
            if cur < err:
                err, clf, mln = cur, nclf, mln + i
                improved = True
                break
    return clf
