"""Unified search subsystem: every strategy yields valid canonical
schedules, agrees with exhaustive enumeration on small spaces, and the
enumerator's stream-bijection pruning (paper §III-C2) is duplicate-free
with a hand-computable class count."""
import itertools
import random

import numpy as np
import pytest

import repro.core as C
import repro.search as S
from repro.core.dag import BoundOp, Graph, Op, OpKind, Schedule


def diamond_dag() -> Graph:
    """4 GPU ops: a -> {b, c} -> d, with distinct fixed durations."""
    g = Graph()
    g.add_op(Op("a", OpKind.GPU, duration=2e-6))
    g.add_op(Op("b", OpKind.GPU, duration=8e-6))
    g.add_op(Op("c", OpKind.GPU, duration=7e-6))
    g.add_op(Op("d", OpKind.GPU, duration=3e-6))
    g.add_edge("a", "b")
    g.add_edge("a", "c")
    g.add_edge("b", "d")
    g.add_edge("c", "d")
    return g.finalize()


def forkjoin_dag() -> Graph:
    """6 ops: CPU load -> 3 parallel GPU kernels -> GPU merge -> store."""
    g = Graph()
    g.add_op(Op("load", OpKind.CPU, duration=1e-6))
    g.add_op(Op("k1", OpKind.GPU, duration=9e-6))
    g.add_op(Op("k2", OpKind.GPU, duration=4e-6))
    g.add_op(Op("k3", OpKind.GPU, duration=5e-6))
    g.add_op(Op("merge", OpKind.GPU, duration=2e-6))
    g.add_op(Op("store", OpKind.CPU, duration=1e-6))
    for k in ("k1", "k2", "k3"):
        g.add_edge("load", k)
        g.add_edge(k, "merge")
    g.add_edge("merge", "store")
    return g.finalize()


def make_strategies(g: Graph, n_streams: int = 2) -> dict:
    return {
        "exhaustive": S.ExhaustiveSearch(g, n_streams),
        "mcts": S.MCTSSearch(g, n_streams, seed=0),
        "random": S.RandomSearch(g, n_streams, seed=0),
        "greedy": S.GreedyCostModel(g, n_streams, seed=0),
    }


# -- validity -----------------------------------------------------------------

@pytest.mark.parametrize("make_dag", [diamond_dag, forkjoin_dag],
                         ids=["diamond", "forkjoin"])
@pytest.mark.parametrize("name", ["exhaustive", "mcts", "random",
                                  "greedy"])
def test_strategy_proposals_valid_and_canonical(make_dag, name):
    g = make_dag()
    strat = make_strategies(g)[name]
    res = S.run_search(g, strat, budget=60)
    assert res.schedules
    for s in res.schedules:
        C.validate_schedule(g, s)
        assert C.canonicalize_streams(s.items) == s.items, \
            f"{name} emitted a non-canonical stream labeling"


def test_strategy_protocol_conformance():
    g = diamond_dag()
    for name, strat in make_strategies(g).items():
        assert isinstance(strat, S.SearchStrategy), name


# -- agreement with exhaustive on the argmin ----------------------------------

@pytest.mark.parametrize("make_dag", [diamond_dag, forkjoin_dag],
                         ids=["diamond", "forkjoin"])
def test_strategies_find_exhaustive_argmin(make_dag):
    """MCTS/random/greedy all reach the exhaustive-search optimum on
    small DAGs (<= 6 ops, 2 streams)."""
    g = make_dag()
    ex = S.run_search(g, S.ExhaustiveSearch(g, 2), budget=None)
    t_best = ex.best()[1]
    assert np.isclose(t_best, min(ex.times))
    budgets = {"mcts": 2000, "random": 400, "greedy": 200}
    for name in ("mcts", "random", "greedy"):
        strat = make_strategies(g)[name]
        res = S.run_search(g, strat, budget=budgets[name])
        assert np.isclose(res.best()[1], t_best), \
            f"{name} best {res.best()[1]} != exhaustive {t_best}"


def test_mcts_strategy_exhausts_small_space():
    g = diamond_dag()
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=3), budget=5000)
    ex = list(C.enumerate_schedules(g, 2))
    assert len(res.schedules) == len(ex)
    assert {S.canonical_key(s) for s in res.schedules} == \
        {S.canonical_key(s) for s in ex}
    # Once fully explored, propose returns nothing more.
    assert res.n_proposed < 5000


# -- run_search pipeline semantics --------------------------------------------

def test_run_search_budget_counts_proposals():
    g = diamond_dag()
    res = S.run_search(g, S.RandomSearch(g, 2, seed=1), budget=50,
                       batch_size=8)
    assert res.n_proposed == 50
    assert len(res.schedules) <= 50
    # duplicates were evaluated via the memo cache
    assert res.cache_hits + res.cache_misses == 50
    assert res.cache_misses == len(res.schedules)


def test_run_search_observations_reach_strategy():
    g = diamond_dag()
    seen: list[float] = []

    class Recorder:
        def __init__(self):
            self.inner = S.RandomSearch(g, 2, seed=0)

        def propose(self, budget):
            return self.inner.propose(budget)

        def observe(self, schedule, time):
            seen.append(time)

    res = S.run_search(g, Recorder(), budget=20)
    assert len(seen) == 20
    assert set(res.times) <= set(seen)


# -- enumeration properties (paper §III-C2 stream-bijection pruning) ----------

def test_diamond_enumeration_matches_hand_count():
    """4-op diamond, 2 streams: 2 topological interleavings of {b, c},
    and per order the first GPU op is pinned to stream 0 (first-use
    canonical form) while each of the remaining 3 ops picks a used
    stream or the one unused stream: 2 * 1 * 2^3 = 16 classes."""
    g = diamond_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    assert len(scheds) == 16

    # Cross-check: brute-force all (order x raw stream assignment) and
    # count distinct canonical forms.
    orders = [("a", "b", "c", "d"), ("a", "c", "b", "d")]
    classes = set()
    for order in orders:
        for streams in itertools.product((0, 1), repeat=4):
            items = [BoundOp("start")] + [
                BoundOp(n, s) for n, s in zip(order, streams)] + \
                [BoundOp("end")]
            classes.add(tuple((i.name, i.stream) for i in
                              C.canonicalize_streams(items)))
    assert len(classes) == 16
    assert {s.key() for s in scheds} == classes


def random_dag(rng: random.Random) -> Graph:
    """Small random DAG: 3-6 ops, random GPU/CPU mix, random forward
    edges (property-test generator; plain seeded random, no deps)."""
    g = Graph()
    n = rng.randint(3, 6)
    names = [f"op{i}" for i in range(n)]
    for name in names:
        kind = OpKind.GPU if rng.random() < 0.6 else OpKind.CPU
        g.add_op(Op(name, kind, duration=rng.uniform(1e-6, 9e-6)))
    for i, j in itertools.combinations(range(n), 2):
        if rng.random() < 0.4:
            g.add_edge(names[i], names[j])
    return g.finalize()


@pytest.mark.parametrize("seed", range(12))
def test_enumerate_no_duplicate_canonical_schedules(seed):
    """Property: the enumerator emits each stream-bijection equivalence
    class exactly once, every emission valid and already canonical."""
    g = random_dag(random.Random(1000 + seed))
    seen = set()
    for s in C.enumerate_schedules(g, 2):
        C.validate_schedule(g, s)
        assert C.canonicalize_streams(s.items) == s.items
        key = S.canonical_key(s)
        assert key not in seen, "duplicate canonical schedule emitted"
        seen.add(key)
    assert seen  # space is never empty


@pytest.mark.parametrize("seed", range(6))
def test_random_schedule_generator_is_valid(seed):
    g = random_dag(random.Random(2000 + seed))
    rng = random.Random(seed)
    for _ in range(10):
        s = S.random_schedule(g, 2, rng)
        C.validate_schedule(g, s)
        assert C.canonicalize_streams(s.items) == s.items
