"""Benchmarks reproducing each paper table/figure on our SpMV space.

Every search below — exhaustive, MCTS, noisy MCTS — runs through the
unified ``repro.search.run_search`` pipeline (one code path with the
examples and the smoke test). Each function returns rows as CSV lines
``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

import repro.core as C
import repro.search as S


def _space(n_streams: int = 2):
    """Exhaustive SpMV design space via the unified search pipeline."""
    g = C.spmv_dag()
    res = S.run_search(g, S.ExhaustiveSearch(g, n_streams), budget=None,
                       batch_size=64)
    return g, res.schedules, res.times_array()


def _mcts(g, iters: int, seed: int, noise_sigma: float = 0.0):
    """MCTS through the same pipeline (batch_size=1: the paper's loop)."""
    evaluator = S.BatchEvaluator(g, noise_sigma=noise_sigma,
                                 noise_seed=7)
    return S.run_search(g, S.MCTSSearch(g, 2, seed=seed), budget=iters,
                        evaluator=evaluator)


def fig1_spread() -> list[str]:
    """Fig. 1: sorted exhaustive-search times; fastest vs slowest."""
    t0 = time.perf_counter()
    g, scheds, times = _space()
    wall = (time.perf_counter() - t0) / max(1, len(scheds)) * 1e6
    s = np.sort(times)
    rows = [
        f"fig1_n_implementations,{wall:.2f},{len(scheds)}",
        f"fig1_speedup_spread,{wall:.2f},{s[-1] / s[0]:.3f}",
        f"fig1_fastest_us,{wall:.2f},{s[0] * 1e6:.2f}",
        f"fig1_slowest_us,{wall:.2f},{s[-1] * 1e6:.2f}",
    ]
    return rows


def fig4_labels() -> list[str]:
    """Fig. 4: convolution + peak detection class labeling."""
    g, scheds, times = _space()
    t0 = time.perf_counter()
    lab = C.label_times(times)
    wall = (time.perf_counter() - t0) * 1e6
    sizes = np.bincount(lab.labels)
    return [
        f"fig4_n_classes,{wall:.2f},{lab.n_classes}",
        f"fig4_class_sizes,{wall:.2f},{'/'.join(map(str, sizes))}",
        f"fig4_boundaries,{wall:.2f},"
        f"{'/'.join(map(str, lab.boundaries.tolist()))}",
    ]


def fig5_tree() -> list[str]:
    """Fig. 5: Algorithm 1 hyperparameter search trace."""
    g, scheds, times = _space()
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    trace = C.TreeSearchTrace([], [], [])
    t0 = time.perf_counter()
    tree = C.algorithm1(fm.X, lab.labels, trace=trace)
    wall = (time.perf_counter() - t0) * 1e6
    return [
        f"fig5_final_leaves,{wall:.2f},{tree.n_leaves()}",
        f"fig5_final_depth,{wall:.2f},{tree.depth()}",
        f"fig5_final_error,{wall:.2f},"
        f"{tree.training_error(fm.X, lab.labels):.4f}",
        f"fig5_trials,{wall:.2f},{len(trace.max_leaf_nodes)}",
    ]


def table5_accuracy() -> list[str]:
    """Table V: MCTS iterations vs class-range accuracy on the full
    space (paper: 0.75/0.83/0.96/0.99/1.0 at 50/100/200/400/2036)."""
    g, scheds, times = _space()
    rows = []
    for iters in (25, 50, 100, 200, 1200):
        t0 = time.perf_counter()
        res = _mcts(g, iters, seed=1)
        fm, lab, _ = res.dataset()
        tree = C.algorithm1(fm.X, lab.labels)
        Xf = C.featurize_like(g, scheds, fm)
        acc = C.class_range_accuracy(tree, Xf, times,
                                     lab.class_ranges())
        wall = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"table5_acc_iters{iters},{wall:.2f},{acc:.3f}")
    return rows


def tables678_rules() -> list[str]:
    """Tables VI-VIII: rulesets per class for reduced MCTS budgets,
    annotated against the canonical (exhaustive) rules."""
    g, scheds, times = _space()
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    canon_tree = C.algorithm1(fm.X, lab.labels)
    canon = C.extract_rulesets(canon_tree, fm.features)
    rows = []
    for iters in (50, 100, 200):
        t0 = time.perf_counter()
        res = _mcts(g, iters, seed=2)
        fm_i, lab_i, _ = res.dataset()
        tree_i = C.algorithm1(fm_i.X, lab_i.labels)
        rs = C.extract_rulesets(tree_i, fm_i.features)
        C.annotate_vs_canonical(rs, canon)
        n_over = sum(bool(r.extraneous) for r in rs)
        n_under = sum(r.insufficient for r in rs)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"tables678_iters{iters},{wall:.2f},"
            f"rulesets={len(rs)}/over={n_over}/under={n_under}")
    # persist the rendered rules for EXPERIMENTS.md
    import pathlib
    out = pathlib.Path(__file__).resolve().parents[1] / "experiments"
    out.mkdir(exist_ok=True)
    grouped = C.rules_by_class(canon)
    (out / "rules_canonical.md").write_text(
        C.render_rules_table(grouped))
    return rows


def stepdag_overlap() -> list[str]:
    """Beyond-paper: the technique applied to our own train step
    (collective-overlap schedule search, TPU machine model)."""
    from repro.core.stepdag import StepCosts, train_step_dag, \
        with_comm_durations
    costs = StepCosts(fwd_flops=2e12, bwd_flops=4e12, fwd_bytes=1e9,
                      bwd_bytes=2e9, grad_bytes=2e9)
    g = with_comm_durations(train_step_dag(4, costs), 50e9)
    t0 = time.perf_counter()
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=400)
    wall = (time.perf_counter() - t0) / 400 * 1e6
    best = min(res.times)
    worst = max(res.times)
    return [
        f"stepdag_best_ms,{wall:.2f},{best * 1e3:.3f}",
        f"stepdag_worst_ms,{wall:.2f},{worst * 1e3:.3f}",
        f"stepdag_speedup,{wall:.2f},{worst / best:.3f}",
    ]


def granularity_ablation() -> list[str]:
    """Beyond-paper: the paper's §III-A granularity trade-off, measured.

    Fine-grained per-neighbor Pack/Send/Recv vertices remove false
    dependencies but (a) explode the space (>5e5 vs 280) and (b) add
    per-op launch/host overhead that outweighs the overlap they enable
    at these message sizes. The fine space is searched with the
    greedy→MCTS→surrogate portfolio (the at-scale recipe; plain MCTS
    vs portfolio is raced head-to-head in benchmarks/at_scale.py)."""
    from repro.core.dag import spmv_dag_fine
    g_fine = spmv_dag_fine()
    t0 = time.perf_counter()
    res = S.run_search(g_fine, S.PortfolioSearch(g_fine, 2, seed=0),
                       budget=2000)
    wall = (time.perf_counter() - t0) / 2000 * 1e6
    tf = res.times_array()
    g_coarse, _, tc = _space()
    return [
        f"granularity_fine_best_us,{wall:.2f},{tf.min() * 1e6:.2f}",
        f"granularity_coarse_best_us,{wall:.2f},{tc.min() * 1e6:.2f}",
        f"granularity_fine_spread,{wall:.2f},{tf.max() / tf.min():.3f}",
        f"granularity_overhead_ratio,{wall:.2f},"
        f"{tf.min() / tc.min():.3f}",
    ]


def noise_robustness() -> list[str]:
    """Beyond-paper: labeling robustness under measurement noise (the
    paper's empirical times are noisy; our machine model lets us dose
    noise explicitly). Reports Table-V-style accuracy at 200 MCTS
    iterations under multiplicative Gaussian noise."""
    g, scheds, times = _space()
    rows = []
    for sigma in (0.0, 0.01, 0.05):
        t0 = time.perf_counter()
        res = _mcts(g, 200, seed=3, noise_sigma=sigma)
        fm, lab, _ = res.dataset()
        tree = C.algorithm1(fm.X, lab.labels)
        Xf = C.featurize_like(g, scheds, fm)
        # widen class ranges by the noise level for the range test
        ranges = [(lo * (1 - 3 * sigma), hi * (1 + 3 * sigma))
                  for lo, hi in lab.class_ranges()]
        acc = C.class_range_accuracy(tree, Xf, times, ranges)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"noise_acc_sigma{sigma},{wall:.2f},"
            f"{acc:.3f}/classes={lab.n_classes}")
    return rows
